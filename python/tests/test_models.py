"""L2 model zoo: shapes, init, aux-classifier semantics, exact Table 2 counts."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.flatparams import ParamSpec
from compile.models import (
    alexnet_proxy,
    googlenet_proxy,
    mlp,
    registry,
    transformer,
    vgg_proxy,
)

PROXIES = [
    ("mlp", mlp),
    ("alexnet", alexnet_proxy),
    ("googlenet", googlenet_proxy),
    ("vgg", vgg_proxy),
]


@pytest.mark.parametrize("name,mod", PROXIES)
def test_init_matches_shapes(name, mod):
    cfg = mod.config()
    shapes = mod.param_shapes(cfg)
    params = mod.init_params(cfg, seed=0)
    assert len(params) == len(shapes)
    for (nm, s), p in zip(shapes, params):
        assert tuple(p.shape) == tuple(s), nm
        assert p.dtype == np.float32, nm


@pytest.mark.parametrize("name,mod", PROXIES)
def test_apply_output_shapes(name, mod):
    cfg = mod.config()
    params = [jnp.asarray(p) for p in mod.init_params(cfg, seed=0)]
    bs = 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(mod.input_shape(cfg, bs)).astype(np.float32))
    logits, auxes = mod.apply(cfg, params, x, train=True)
    assert logits.shape == (bs, cfg["classes"])
    for a in auxes:
        assert a.shape == (bs, cfg["classes"])


def test_googlenet_aux_heads_train_only():
    cfg = googlenet_proxy.config()
    params = [jnp.asarray(p) for p in googlenet_proxy.init_params(cfg, seed=0)]
    x = jnp.zeros(googlenet_proxy.input_shape(cfg, 2), jnp.float32)
    _, aux_train = googlenet_proxy.apply(cfg, params, x, train=True)
    _, aux_eval = googlenet_proxy.apply(cfg, params, x, train=False)
    assert len(aux_train) == len(cfg["aux_after"]) == 2  # paper footnote 12
    assert aux_eval == []


def test_alexnet_proxy_has_8_weighted_layers():
    cfg = alexnet_proxy.config()
    weighted = [n for n, _ in alexnet_proxy.param_shapes(cfg) if n.endswith("_w")]
    assert len(weighted) == 8  # Table 2: AlexNet depth 8


def test_transformer_shapes_and_loss():
    cfg = transformer.config(vocab=64, d_model=32, n_layer=2, n_head=2, d_ff=64, seq_len=16)
    params = [jnp.asarray(p) for p in transformer.init_params(cfg, seed=0)]
    toks = jnp.zeros((2, 16), jnp.int32)
    logits, aux = transformer.apply(cfg, params, toks, train=True)
    assert logits.shape == (2, 16, 64)
    assert aux == []
    loss = transformer.lm_loss(logits, toks)
    # untrained loss ~= ln(vocab)
    assert abs(float(loss) - np.log(64)) < 0.5


def test_flatten_unflatten_roundtrip():
    cfg = mlp.config()
    spec = ParamSpec(mlp.param_shapes(cfg))
    params = [jnp.asarray(p) for p in mlp.init_params(cfg, seed=3)]
    flat = spec.flatten(params)
    assert flat.shape == (spec.total,)
    back = spec.unflatten(flat)
    for a, b in zip(params, back):
        np.testing.assert_array_equal(a, b)


# --- the paper's Table 2, exactly -------------------------------------------


@pytest.mark.parametrize("name", ["alexnet", "googlenet", "vggnet"])
def test_registry_exact_paper_counts(name):
    assert registry.total_params(name) == registry.PAPER_COUNTS[name]


def test_registry_depths_match_paper():
    assert registry.FULL_SCALE["alexnet"]["depth"] == 8
    assert registry.FULL_SCALE["googlenet"]["depth"] == 22
    assert registry.FULL_SCALE["vggnet"]["depth"] == 19  # as reported (count matches VGG-D)


def test_registry_googlenet_includes_both_aux_heads():
    names = [n for n, _ in registry.segments("googlenet")]
    assert any(n.startswith("loss1/") for n in names)
    assert any(n.startswith("loss2/") for n in names)
    assert "loss3/classifier" in names


def test_registry_segments_positive_and_ordered():
    for m in ("alexnet", "googlenet", "vggnet"):
        segs = registry.segments(m)
        assert all(sz > 0 for _, sz in segs)
        assert len({n for n, _ in segs}) == len(segs)  # unique names
