"""AOT artifact + manifest consistency (runs after `make artifacts`)."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_every_artifact_file_exists_and_is_hlo_text():
    m = _manifest()
    for name, a in m["artifacts"].items():
        p = os.path.join(ART, a["file"])
        assert os.path.exists(p), name
        with open(p) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), name


def test_models_reference_existing_artifacts():
    m = _manifest()
    for name, info in m["models"].items():
        for bs, key in info["batches"].items():
            for suffix in ("_train", "_grad"):
                assert key + suffix in m["artifacts"], (name, key + suffix)
        assert f"{name}_eval" in m["artifacts"]
        assert info["sgd_apply"] in m["artifacts"]


def test_init_bins_match_param_counts():
    m = _manifest()
    for name, info in m["models"].items():
        p = os.path.join(ART, info["init_file"])
        data = np.fromfile(p, dtype="<f4")
        assert data.shape[0] == info["param_count"], name
        assert np.all(np.isfinite(data)), name


def test_segments_partition_the_flat_vector():
    m = _manifest()
    for name, info in m["models"].items():
        off = 0
        for nm, o, sz in info["segments"]:
            assert o == off, (name, nm)
            off += sz
        assert off == info["param_count"], name


def test_train_signatures_flat_param_convention():
    m = _manifest()
    for name, info in m["models"].items():
        n = info["param_count"]
        art = m["artifacts"][info["batches"][str(info["batch"])] + "_train"]
        ins, outs = art["inputs"], art["outputs"]
        assert ins[0]["shape"] == [n] and ins[0]["dtype"] == "f32"  # params
        assert ins[1]["shape"] == [n] and ins[1]["dtype"] == "f32"  # momentum
        assert ins[4]["shape"] == [] and ins[5]["shape"] == []      # lr, mu
        assert outs[0]["shape"] == [n] and outs[1]["shape"] == [n]
        assert outs[2]["shape"] == []                               # loss


def test_full_scale_table2_exact():
    m = _manifest()
    fs = m["full_scale"]
    assert fs["alexnet"]["params"] == 60_965_224
    assert fs["googlenet"]["params"] == 13_378_280
    assert fs["vggnet"]["params"] == 138_357_544
    for info in fs.values():
        assert info["params"] == info["paper_params"]
        assert sum(sz for _, sz in info["segments"]) == info["params"]


def test_kernel_artifacts_present():
    m = _manifest()
    k = m["kernels"]
    assert k["chunk"] == 1 << 20  # §Perf: 1M chunks keep the ASA path off the PJRT call-overhead wall
    for key in list(k["sum_stack"].values()) + list(k["fp16_pack"].values()) + list(
        k["fp16_unpack"].values()
    ):
        assert key in m["artifacts"], key
