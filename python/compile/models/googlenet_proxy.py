"""GoogLeNet proxy: stem + inception blocks + two auxiliary classifiers.

Mirrors the BVLC GoogLeNet structure the paper benchmarked (inception modules
with 1x1 / 3x3-reduce / 5x5-reduce / pool-proj branches; aux classifiers with
the 0.3 loss weight) at 32x32 with scaled channels. The exact 13,378,280
full-scale parameter table (incl. both aux heads, paper footnote 12) is in
`registry.py`.
"""

import jax.numpy as jnp
import numpy as np

from . import nn

# branch spec: (c1, c3r, c3, c5r, c5, cpool)
def config(**kw):
    cfg = dict(
        in_hw=32,
        classes=16,
        batch=32,
        eval_batch=128,
        stem=32,
        blocks=[
            # (in resolution after stem pool = 16)
            dict(spec=(16, 16, 24, 4, 8, 8), pool_after=False),
            dict(spec=(24, 24, 32, 8, 16, 16), pool_after=True),
            dict(spec=(32, 32, 48, 8, 16, 16), pool_after=False),
        ],
        aux_after=[1, 2],  # block indices with auxiliary heads
        aux_proj=16,
        aux_fc=64,
        aux_weight=0.3,
    )
    cfg.update(kw)
    return cfg


def _block_out(spec):
    c1, c3r, c3, c5r, c5, cp = spec
    return c1 + c3 + c5 + cp


def param_shapes(cfg):
    shapes = [
        ("stem_w", (cfg["stem"], 3, 3, 3)),
        ("stem_b", (cfg["stem"],)),
    ]
    in_c = cfg["stem"]
    for bi, blk in enumerate(cfg["blocks"]):
        c1, c3r, c3, c5r, c5, cp = blk["spec"]
        p = f"inc{bi}_"
        shapes += [
            (p + "b1_w", (c1, in_c, 1, 1)), (p + "b1_b", (c1,)),
            (p + "b3r_w", (c3r, in_c, 1, 1)), (p + "b3r_b", (c3r,)),
            (p + "b3_w", (c3, c3r, 3, 3)), (p + "b3_b", (c3,)),
            (p + "b5r_w", (c5r, in_c, 1, 1)), (p + "b5r_b", (c5r,)),
            (p + "b5_w", (c5, c5r, 5, 5)), (p + "b5_b", (c5,)),
            (p + "bp_w", (cp, in_c, 1, 1)), (p + "bp_b", (cp,)),
        ]
        in_c = _block_out(blk["spec"])
        if bi in cfg["aux_after"]:
            a = f"aux{bi}_"
            shapes += [
                (a + "proj_w", (cfg["aux_proj"], in_c, 1, 1)),
                (a + "proj_b", (cfg["aux_proj"],)),
                # aux avg-pools to 4x4 before the projection
                (a + "fc1_w", (cfg["aux_proj"] * 16, cfg["aux_fc"])),
                (a + "fc1_b", (cfg["aux_fc"],)),
                (a + "fc2_w", (cfg["aux_fc"], cfg["classes"])),
                (a + "fc2_b", (cfg["classes"],)),
            ]
    shapes += [
        ("head_w", (in_c, cfg["classes"])),
        ("head_b", (cfg["classes"],)),
    ]
    return shapes


def init_params(cfg, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in param_shapes(cfg):
        if name.endswith("_w") and len(shape) == 4:
            out.append(nn.he_conv(rng, shape[0], shape[1], shape[2], shape[3]))
        elif name.endswith("_w"):
            out.append(nn.he_fc(rng, *shape))
        else:
            out.append(nn.zeros(*shape))
    return out


def input_shape(cfg, batch):
    return (batch, 3, cfg["in_hw"], cfg["in_hw"])


def _inception(h, p, i):
    """Apply one inception module; p is the param list, i the cursor."""
    b1 = nn.relu(nn.conv2d(h, p[i], p[i + 1]))
    b3 = nn.relu(nn.conv2d(h, p[i + 2], p[i + 3]))
    b3 = nn.relu(nn.conv2d(b3, p[i + 4], p[i + 5]))
    b5 = nn.relu(nn.conv2d(h, p[i + 6], p[i + 7]))
    b5 = nn.relu(nn.conv2d(b5, p[i + 8], p[i + 9]))
    # pool branch: 3x3/1 max pool at constant resolution (edge-padded)
    bp = jnp.pad(h, ((0, 0), (0, 0), (1, 1), (1, 1)), mode="edge")
    bp = nn.max_pool(bp, size=3, stride=1)
    bp = nn.relu(nn.conv2d(bp, p[i + 10], p[i + 11]))
    return jnp.concatenate([b1, b3, b5, bp], axis=1), i + 12


def _aux_head(h, p, i, cfg):
    """Aux classifier: avg-pool to 4x4 -> 1x1 conv -> fc -> fc."""
    hw = h.shape[2]
    a = nn.avg_pool(h, size=hw // 4, stride=hw // 4)
    a = nn.relu(nn.conv2d(a, p[i], p[i + 1]))
    a = nn.flatten(a)
    a = nn.relu(nn.dense(a, p[i + 2], p[i + 3]))
    a = nn.dense(a, p[i + 4], p[i + 5])
    return a, i + 6


def apply(cfg, params, x, train=True):
    h = nn.relu(nn.conv2d(x, params[0], params[1]))
    h = nn.max_pool(h)
    i = 2
    auxes = []
    for bi, blk in enumerate(cfg["blocks"]):
        h, i = _inception(h, params, i)
        if bi in cfg["aux_after"]:
            a, i = _aux_head(h, params, i, cfg)
            if train:
                auxes.append(a)
        if blk["pool_after"]:
            h = nn.max_pool(h)
    h = nn.global_avg_pool(h)
    logits = nn.dense(h, params[i], params[i + 1])
    return logits, auxes
