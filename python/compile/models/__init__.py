"""Model zoo for the reproduction.

Each model module exposes:
  config(**overrides) -> dict          hyper-parameter dict
  param_shapes(cfg)   -> [(name, shape)]
  init_params(cfg, seed) -> [np.ndarray]   deterministic He/Glorot init
  apply(cfg, params, x, train) -> (logits, [aux_logits...])

`registry` carries the *full-scale* architectures' exact layer tables (paper
Table 2 parameter counts) that drive the rust communication simulator; the
modules here are the runnable reduced-resolution proxies (DESIGN.md §2).
"""

from . import alexnet_proxy, googlenet_proxy, mlp, registry, transformer, vgg_proxy  # noqa: F401
