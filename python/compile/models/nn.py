"""Shared layer primitives for the proxy CNNs (NCHW, jax.lax convs).

Convolutions lower through XLA's conv (the paper likewise used cuDNN rather
than custom conv kernels); all fully-connected layers go through the L1
Pallas matmul so every model's hot path exercises the kernel.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.matmul import matmul as pallas_matmul


def conv2d(x, w, b, stride=1, padding="SAME"):
    """NCHW conv + bias. w: (out_c, in_c, kh, kw)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def max_pool(x, size=2, stride=2):
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, 1, size, size),
        (1, 1, stride, stride),
        "VALID",
    )


def avg_pool(x, size, stride):
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, size, size), (1, 1, stride, stride), "VALID"
    )
    return s / float(size * size)


def global_avg_pool(x):
    return jnp.mean(x, axis=(2, 3))


def relu(x):
    return jnp.maximum(x, 0.0)


def dense(x, w, b):
    """FC layer through the Pallas tiled matmul (L1 on the hot path)."""
    return pallas_matmul(x, w) + b[None, :]


def flatten(x):
    return jnp.reshape(x, (x.shape[0], -1))


# ---------------------------------------------------------------------------
# deterministic init helpers (numpy, seeded)


def he_conv(rng: np.random.RandomState, out_c, in_c, kh, kw):
    fan_in = in_c * kh * kw
    std = math.sqrt(2.0 / fan_in)
    return (rng.randn(out_c, in_c, kh, kw) * std).astype(np.float32)


def he_fc(rng: np.random.RandomState, n_in, n_out):
    std = math.sqrt(2.0 / n_in)
    return (rng.randn(n_in, n_out) * std).astype(np.float32)


def zeros(*shape):
    return np.zeros(shape, np.float32)


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def correct_count(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
