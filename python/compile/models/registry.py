"""Full-scale architecture registry — the paper's Table 2, exactly.

These are the layer tables of the *actual* AlexNet / GoogLeNet / VGGNet the
paper benchmarked, as (name, parameter-count) segments. They drive the rust
communication simulator: exchange cost depends only on parameter bytes and
their per-layer segmentation, so Table 3 / Fig 3 reproduce at true scale even
though the runnable proxies are reduced.

Expected totals (paper Table 2):
  AlexNet   60,965,224   (Krizhevsky two-tower: grouped conv2/4/5)
  GoogLeNet 13,378,280   (BVLC table incl. BOTH aux classifiers, footnote 12)
  VGGNet   138,357,544   (paper reports depth 19; the count matches the
                          16-weighted-layer VGG-D config — we encode VGG-D and
                          keep the paper's reported depth in the metadata)

python/tests/test_registry.py asserts the totals; rust tests assert the same
numbers from manifest.json (Table 2 regeneration).
"""


def _conv(name, kh, kw, in_c, out_c, groups=1):
    return (name, (kh * kw * (in_c // groups) * out_c) + out_c)


def _fc(name, n_in, n_out):
    return (name, n_in * n_out + n_out)


def alexnet_layers():
    return [
        _conv("conv1", 11, 11, 3, 96),
        _conv("conv2", 5, 5, 96, 256, groups=2),
        _conv("conv3", 3, 3, 256, 384),
        _conv("conv4", 3, 3, 384, 384, groups=2),
        _conv("conv5", 3, 3, 384, 256, groups=2),
        _fc("fc6", 9216, 4096),
        _fc("fc7", 4096, 4096),
        _fc("fc8", 4096, 1000),
    ]


def _inception(name, in_c, c1, c3r, c3, c5r, c5, cp):
    return [
        _conv(f"{name}/1x1", 1, 1, in_c, c1),
        _conv(f"{name}/3x3_reduce", 1, 1, in_c, c3r),
        _conv(f"{name}/3x3", 3, 3, c3r, c3),
        _conv(f"{name}/5x5_reduce", 1, 1, in_c, c5r),
        _conv(f"{name}/5x5", 5, 5, c5r, c5),
        _conv(f"{name}/pool_proj", 1, 1, in_c, cp),
    ]


def _aux(name, in_c):
    # avg-pool 5x5/3 to 4x4, 1x1 conv to 128, fc 2048->1024, fc 1024->1000
    return [
        _conv(f"{name}/conv", 1, 1, in_c, 128),
        _fc(f"{name}/fc", 128 * 4 * 4, 1024),
        _fc(f"{name}/classifier", 1024, 1000),
    ]


def googlenet_layers():
    layers = [
        _conv("conv1/7x7_s2", 7, 7, 3, 64),
        _conv("conv2/3x3_reduce", 1, 1, 64, 64),
        _conv("conv2/3x3", 3, 3, 64, 192),
    ]
    layers += _inception("inception_3a", 192, 64, 96, 128, 16, 32, 32)    # out 256
    layers += _inception("inception_3b", 256, 128, 128, 192, 32, 96, 64)  # out 480
    layers += _inception("inception_4a", 480, 192, 96, 208, 16, 48, 64)   # out 512
    layers += _aux("loss1", 512)
    layers += _inception("inception_4b", 512, 160, 112, 224, 24, 64, 64)  # out 512
    layers += _inception("inception_4c", 512, 128, 128, 256, 24, 64, 64)  # out 512
    layers += _inception("inception_4d", 512, 112, 144, 288, 32, 64, 64)  # out 528
    layers += _aux("loss2", 528)
    layers += _inception("inception_4e", 528, 256, 160, 320, 32, 128, 128)  # out 832
    layers += _inception("inception_5a", 832, 256, 160, 320, 32, 128, 128)  # out 832
    layers += _inception("inception_5b", 832, 384, 192, 384, 48, 128, 128)  # out 1024
    layers += [_fc("loss3/classifier", 1024, 1000)]
    return layers


def vgg_layers():
    cfg = [  # VGG-D: (in, out) per 3x3 conv
        (3, 64), (64, 64),
        (64, 128), (128, 128),
        (128, 256), (256, 256), (256, 256),
        (256, 512), (512, 512), (512, 512),
        (512, 512), (512, 512), (512, 512),
    ]
    layers = [_conv(f"conv{i + 1}", 3, 3, i_c, o_c) for i, (i_c, o_c) in enumerate(cfg)]
    layers += [_fc("fc6", 25088, 4096), _fc("fc7", 4096, 4096), _fc("fc8", 4096, 1000)]
    return layers


FULL_SCALE = {
    # name -> (reported depth, layer table builder, per-worker batch sizes
    #          used in the paper's benchmarks)
    "alexnet": dict(depth=8, layers=alexnet_layers, batches=(128, 32)),
    "googlenet": dict(depth=22, layers=googlenet_layers, batches=(32,)),
    "vggnet": dict(depth=19, layers=vgg_layers, batches=(32,)),
}

PAPER_COUNTS = {
    "alexnet": 60_965_224,
    "googlenet": 13_378_280,
    "vggnet": 138_357_544,
}


def total_params(name: str) -> int:
    return sum(n for _, n in FULL_SCALE[name]["layers"]())


def segments(name: str):
    """(layer name, param count) in exchange order — the ASA split points."""
    return FULL_SCALE[name]["layers"]()
