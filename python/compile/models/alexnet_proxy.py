"""AlexNet proxy: the paper's 8-weighted-layer topology at 32x32 resolution.

Same layer *sequence* as Krizhevsky's AlexNet (5 convs with pools after
1/2/5, then 3 FCs) with channels scaled for a single-CPU-core testbed. The
full-scale layer table — exactly 60,965,224 parameters — lives in
`registry.py` and drives the rust communication simulator; this proxy
provides real convergence dynamics (Fig. 4, Table 1 rows).
"""

import numpy as np

from . import nn


def config(**kw):
    cfg = dict(
        in_hw=32,
        classes=16,
        batch=32,
        eval_batch=128,
        convs=[
            # (out_c, kernel, stride, pool_after)
            (32, 3, 1, True),
            (64, 3, 1, True),
            (96, 3, 1, False),
            (64, 3, 1, False),
            (64, 3, 1, True),
        ],
        fc=(256, 128),
    )
    cfg.update(kw)
    return cfg


def _dims(cfg):
    hw = cfg["in_hw"]
    in_c = 3
    dims = []
    for out_c, k, s, pool in cfg["convs"]:
        dims.append((in_c, out_c, k))
        hw = hw // s
        if pool:
            hw //= 2
        in_c = out_c
    return dims, in_c * hw * hw


def param_shapes(cfg):
    dims, flat = _dims(cfg)
    shapes = []
    for i, (in_c, out_c, k) in enumerate(dims):
        shapes.append((f"conv{i + 1}_w", (out_c, in_c, k, k)))
        shapes.append((f"conv{i + 1}_b", (out_c,)))
    fc_dims = [flat, *cfg["fc"], cfg["classes"]]
    for i in range(len(fc_dims) - 1):
        shapes.append((f"fc{i + 6}_w", (fc_dims[i], fc_dims[i + 1])))
        shapes.append((f"fc{i + 6}_b", (fc_dims[i + 1],)))
    return shapes


def init_params(cfg, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in param_shapes(cfg):
        if name.startswith("conv") and name.endswith("_w"):
            out.append(nn.he_conv(rng, *shape[:2], shape[2], shape[3]))
        elif name.endswith("_w"):
            out.append(nn.he_fc(rng, *shape))
        else:
            out.append(nn.zeros(*shape))
    return out


def input_shape(cfg, batch):
    return (batch, 3, cfg["in_hw"], cfg["in_hw"])


def apply(cfg, params, x, train=True):
    i = 0
    h = x
    for out_c, k, s, pool in cfg["convs"]:
        h = nn.relu(nn.conv2d(h, params[i], params[i + 1], stride=s))
        if pool:
            h = nn.max_pool(h)
        i += 2
    h = nn.flatten(h)
    n_fc = len(cfg["fc"]) + 1
    for j in range(n_fc):
        h = nn.dense(h, params[i], params[i + 1])
        if j < n_fc - 1:
            h = nn.relu(h)
        i += 2
    return h, []
