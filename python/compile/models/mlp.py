"""MLP on flat synthetic features — the quickstart / fast-iteration model.

Small enough that BSP convergence experiments run thousands of iterations in
seconds, which is what the scheme-equivalence (AWAGD vs SUBGD) and
effective-batch-size studies use before the CNN proxies confirm the shape.
"""

import numpy as np

from . import nn


def config(**kw):
    cfg = dict(in_dim=256, hidden=(512, 256), classes=16, batch=32, eval_batch=256)
    cfg.update(kw)
    return cfg


def param_shapes(cfg):
    dims = [cfg["in_dim"], *cfg["hidden"], cfg["classes"]]
    shapes = []
    for i in range(len(dims) - 1):
        shapes.append((f"fc{i}_w", (dims[i], dims[i + 1])))
        shapes.append((f"fc{i}_b", (dims[i + 1],)))
    return shapes


def init_params(cfg, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in param_shapes(cfg):
        if name.endswith("_w"):
            out.append(nn.he_fc(rng, *shape))
        else:
            out.append(nn.zeros(*shape))
    return out


def input_shape(cfg, batch):
    return (batch, cfg["in_dim"])


def apply(cfg, params, x, train=True):
    n_layers = len(cfg["hidden"]) + 1
    h = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = nn.dense(h, w, b)
        if i < n_layers - 1:
            h = nn.relu(h)
    return h, []
