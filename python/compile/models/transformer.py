"""Decoder-only transformer LM — the end-to-end validation workload.

The mandated e2e driver (examples/e2e_train_transformer.rs) trains this model
with BSP data parallelism across simulated workers for a few hundred steps on
a synthetic Markov corpus and logs the loss curve. All dense projections (QKV,
attention out, MLP, LM head) run through the L1 Pallas matmul, so the Pallas
kernel sits on the forward AND backward hot path of the e2e artifact.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.matmul import matmul as pallas_matmul


def config(**kw):
    # Default size (~10.5M params) is chosen for the single-CPU-core testbed:
    # the mandated e2e run does a few hundred BSP steps across multiple
    # simulated workers whose compute serializes on one core, so step time
    # (~1.5-2 s at this size) bounds the recorded run to minutes, not hours.
    # Scale up via config overrides on real hardware.
    cfg = dict(
        vocab=2048,
        d_model=384,
        n_layer=5,
        n_head=6,
        d_ff=1536,
        seq_len=96,
        batch=4,
        eval_batch=8,
    )
    cfg.update(kw)
    assert cfg["d_model"] % cfg["n_head"] == 0
    return cfg


def param_shapes(cfg):
    d, f, v, L = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["seq_len"]
    shapes = [
        ("tok_emb", (v, d)),
        ("pos_emb", (L, d)),
    ]
    for i in range(cfg["n_layer"]):
        p = f"l{i}_"
        shapes += [
            (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
            (p + "wqkv", (d, 3 * d)), (p + "bqkv", (3 * d,)),
            (p + "wo", (d, d)), (p + "bo", (d,)),
            (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
        ]
    shapes += [
        ("lnf_g", (d,)), ("lnf_b", (d,)),
        ("head", (d, v)),
    ]
    return shapes


def param_count(cfg):
    return sum(int(np.prod(s)) for _, s in param_shapes(cfg))


def init_params(cfg, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    d = cfg["d_model"]
    for name, shape in param_shapes(cfg):
        if name.endswith(("_g",)):
            out.append(np.ones(shape, np.float32))
        elif name.endswith(("_b", "bqkv", "bo", "b1", "b2")):
            out.append(np.zeros(shape, np.float32))
        elif name in ("tok_emb", "pos_emb"):
            out.append((rng.randn(*shape) * 0.02).astype(np.float32))
        else:
            std = 0.02 / math.sqrt(2 * cfg["n_layer"]) if name.endswith(("wo", "w2")) else 0.02
            out.append((rng.randn(*shape) * std).astype(np.float32))
    return out


def input_shape(cfg, batch):
    return (batch, cfg["seq_len"])  # int32 token ids


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _dense(x2d, w, b):
    return pallas_matmul(x2d, w) + b[None, :]


def apply(cfg, params, tokens, train=True):
    """tokens: i32[B, L] -> logits f32[B, L, V]."""
    d, H = cfg["d_model"], cfg["n_head"]
    hd = d // H
    B, L = tokens.shape
    p = {name: t for (name, _), t in zip(param_shapes(cfg), params)}

    h = p["tok_emb"][tokens] + p["pos_emb"][None, :L, :]
    mask = jnp.tril(jnp.ones((L, L), jnp.float32))
    neg = jnp.float32(-1e9)

    for i in range(cfg["n_layer"]):
        pre = f"l{i}_"
        x = _layer_norm(h, p[pre + "ln1_g"], p[pre + "ln1_b"])
        qkv = _dense(x.reshape(B * L, d), p[pre + "wqkv"], p[pre + "bqkv"])
        qkv = qkv.reshape(B, L, 3, H, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        att = jnp.where(mask[None, None], att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B * L, d)
        h = h + _dense(o, p[pre + "wo"], p[pre + "bo"]).reshape(B, L, d)

        x = _layer_norm(h, p[pre + "ln2_g"], p[pre + "ln2_b"])
        m = _dense(x.reshape(B * L, d), p[pre + "w1"], p[pre + "b1"])
        m = jax.nn.gelu(m)
        m = _dense(m, p[pre + "w2"], p[pre + "b2"])
        h = h + m.reshape(B, L, d)

    h = _layer_norm(h, p["lnf_g"], p["lnf_b"])
    logits = _dense(h.reshape(B * L, d), p["head"], jnp.zeros((cfg["vocab"],), jnp.float32))
    return logits.reshape(B, L, cfg["vocab"]), []


def lm_loss(logits, targets):
    """Next-token cross entropy. targets: i32[B, L]."""
    V = logits.shape[-1]
    flat = logits.reshape(-1, V)
    t = targets.reshape(-1).astype(jnp.int32)
    logz = jax.nn.logsumexp(flat, axis=-1)
    picked = jnp.take_along_axis(flat, t[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def token_correct(logits, targets):
    pred = jnp.argmax(logits, axis=-1)
    return jnp.sum((pred == targets).astype(jnp.int32))
