"""VGGNet proxy: stacked 3x3 conv blocks + heavy FC head, at 32x32.

VGG is the paper's communication stress test (138.4M parameters, Table 3's
worst scaling); the proxy keeps the signature VGG shape — uniform 3x3 convs
in doubling-width blocks and an FC head that dominates the parameter count —
so the proxy, like the original, is FC/comm-heavy relative to its compute.
"""

import numpy as np

from . import nn


def config(**kw):
    cfg = dict(
        in_hw=32,
        classes=16,
        batch=32,
        eval_batch=128,
        blocks=[(32, 2), (64, 2), (128, 2)],  # (channels, convs per block)
        fc=(256,),
    )
    cfg.update(kw)
    return cfg


def param_shapes(cfg):
    shapes = []
    in_c = 3
    hw = cfg["in_hw"]
    li = 0
    for out_c, reps in cfg["blocks"]:
        for _ in range(reps):
            li += 1
            shapes.append((f"conv{li}_w", (out_c, in_c, 3, 3)))
            shapes.append((f"conv{li}_b", (out_c,)))
            in_c = out_c
        hw //= 2
    fc_dims = [in_c * hw * hw, *cfg["fc"], cfg["classes"]]
    for i in range(len(fc_dims) - 1):
        shapes.append((f"fc{i + 1}_w", (fc_dims[i], fc_dims[i + 1])))
        shapes.append((f"fc{i + 1}_b", (fc_dims[i + 1],)))
    return shapes


def init_params(cfg, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for name, shape in param_shapes(cfg):
        if name.startswith("conv") and name.endswith("_w"):
            out.append(nn.he_conv(rng, shape[0], shape[1], shape[2], shape[3]))
        elif name.endswith("_w"):
            out.append(nn.he_fc(rng, *shape))
        else:
            out.append(nn.zeros(*shape))
    return out


def input_shape(cfg, batch):
    return (batch, 3, cfg["in_hw"], cfg["in_hw"])


def apply(cfg, params, x, train=True):
    h = x
    i = 0
    for out_c, reps in cfg["blocks"]:
        for _ in range(reps):
            h = nn.relu(nn.conv2d(h, params[i], params[i + 1]))
            i += 2
        h = nn.max_pool(h)
    h = nn.flatten(h)
    n_fc = len(cfg["fc"]) + 1
    for j in range(n_fc):
        h = nn.dense(h, params[i], params[i + 1])
        if j < n_fc - 1:
            h = nn.relu(h)
        i += 2
    return h, []
