"""L1: Pallas tiled matmul — the dense-layer hot spot of every model here.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's dense
layers run through cuDNN GEMM on K80s. On the TPU-flavoured Pallas model the
equivalent is an MXU-shaped blocked matmul: blocks are multiples of (8, 128),
the K reduction walks grid axis 2 with the f32 accumulator resident in VMEM
(revisited output block), and HBM<->VMEM movement is expressed by BlockSpec
index maps instead of CUDA threadblocks.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; interpret mode lowers the same grid walk to
plain HLO (fori_loop of dynamic-slice / dot / dynamic-update-slice), which is
what the rust runtime loads.

Differentiability: pallas_call has no autodiff rule, so `matmul` carries a
custom VJP built from the same kernel (dx = dy @ w.T, dw = x.T @ dy) — the
backward pass of the AOT train-step artifacts therefore also runs the Pallas
kernel.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One (bm, bn) output block; grid axis 2 walks the K dimension.

    The output block is revisited across k-steps, so the f32 accumulator
    lives in the (VMEM) output ref — initialized at k==0, accumulated after.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block(dim: int, target: int, align: int) -> int:
    """Largest MXU-aligned block <= target that does not over-pad `dim`."""
    if dim <= target:
        return _ceil_to(dim, align)
    return target


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(x, w, block_m: int = 256, block_n: int = 256, block_k: int = 512):
    """Blocked matmul via Pallas: (m, k) @ (k, n) -> (m, n), f32.

    Shapes need not be block-aligned: inputs are zero-padded to the block
    grid and the result is sliced back. Zero padding is exact for matmul.
    """
    return _matmul_fwd_impl(x, w, block_m, block_n, block_k)


def _matmul_fwd_impl(x, w, block_m, block_n, block_k):
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"matmul inner dims mismatch: {x.shape} @ {w.shape}"

    bm = _pick_block(m, block_m, 8)
    bn = _pick_block(n, block_n, 128)
    bk = _pick_block(kdim, block_k, 128)

    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(kdim, bk)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - kdim)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - kdim), (0, np_ - n)))

    n_k = kp // bk
    grid = (mp // bm, np_ // bn, n_k)

    out = pl.pallas_call(
        partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _matmul_vjp_fwd(x, w, block_m, block_n, block_k):
    y = _matmul_fwd_impl(x, w, block_m, block_n, block_k)
    return y, (x, w)


def _matmul_vjp_bwd(block_m, block_n, block_k, res, dy):
    x, w = res
    # Both cotangents run the same Pallas kernel (transposed operands).
    dx = _matmul_fwd_impl(dy, w.T, block_m, block_n, block_k)
    dw = _matmul_fwd_impl(x.T, dy, block_m, block_n, block_k)
    return dx, dw


matmul.defvjp(_matmul_vjp_fwd, _matmul_vjp_bwd)


def vmem_footprint_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Estimated VMEM residency of one grid step (f32): x + w + out blocks.

    Used by DESIGN.md §Perf to keep blocks inside a 16 MB VMEM budget."""
    return 4 * (block_m * block_k + block_k * block_n + block_m * block_n)
