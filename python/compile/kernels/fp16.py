"""L1: Pallas fp16 pack/unpack — the ASA16 wire format (paper §3.2).

Theano-MPI transfers parameters at half precision while summing at full
precision, roughly halving wire bytes (Fig. 3: ~6x faster communication than
MPI_Allreduce). The pack kernel casts f32 -> IEEE half and bitcasts to u16
(the interchange dtype the rust runtime understands natively); unpack
reverses. Rounding is XLA's default f32->f16 round-to-nearest-even, which the
rust `precision` module mirrors bit-exactly (property-tested on both sides).

On real TPU hardware the natural wire format is bf16 (what the MXU consumes);
both paths are built and ASA16 picks via config. IEEE f16 is the default to
match the paper's CUDA half type.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, o_ref, *, wire_dtype):
    h = x_ref[...].astype(wire_dtype)
    o_ref[...] = jax.lax.bitcast_convert_type(h, jnp.uint16)


def _unpack_kernel(b_ref, o_ref, *, wire_dtype):
    h = jax.lax.bitcast_convert_type(b_ref[...], wire_dtype)
    o_ref[...] = h.astype(jnp.float32)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _blocked_1d(kernel, x, out_dtype, block_n: int):
    (n,) = x.shape
    bn = min(block_n, _ceil_to(n, 128))
    np_ = _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, np_ - n),))
    out = pl.pallas_call(
        kernel,
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), out_dtype),
        interpret=True,
    )(xp)
    return out[:n]


def fp16_pack(x, block_n: int = 65536, wire: str = "f16"):
    """f32[n] -> u16[n] half bits (wire='f16' IEEE half, 'bf16' bfloat16)."""
    dt = jnp.float16 if wire == "f16" else jnp.bfloat16
    return _blocked_1d(partial(_pack_kernel, wire_dtype=dt), x.astype(jnp.float32), jnp.uint16, block_n)


def fp16_unpack(bits, block_n: int = 65536, wire: str = "f16"):
    """u16[n] half bits -> f32[n]."""
    dt = jnp.float16 if wire == "f16" else jnp.bfloat16
    return _blocked_1d(partial(_unpack_kernel, wire_dtype=dt), bits, jnp.float32, block_n)


def pack_entry(n: int, wire: str = "f16"):
    def fn(x):
        # single grid step for the AOT artifact (see sgd.apply_entry)
        return (fp16_pack(x, block_n=n, wire=wire),)

    return fn, (jax.ShapeDtypeStruct((n,), jnp.float32),)


def unpack_entry(n: int, wire: str = "f16"):
    def fn(bits):
        return (fp16_unpack(bits, block_n=n, wire=wire),)

    return fn, (jax.ShapeDtypeStruct((n,), jnp.uint16),)
