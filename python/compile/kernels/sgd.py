"""L1: Pallas fused momentum-SGD update over the flat parameter vector.

The paper's update stage (§4) applies classical momentum SGD per worker; in
SUBGD the summed gradient is applied once after the exchange. Fusing
`v' = mu*v - lr*(g*scale); w' = w + v'` into one Pallas kernel keeps the
whole update a single pass over HBM (3 reads + 2 writes per element) instead
of XLA's default elementwise graph — and it is the `sgd_apply_*` artifact the
rust SUBGD scheme executes after summing gradients.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_kernel(w_ref, v_ref, g_ref, s_ref, w_out, v_out):
    # s_ref packs (lr, mu, scale) as a broadcast-read f32[4] block (padded).
    lr = s_ref[0]
    mu = s_ref[1]
    scale = s_ref[2]
    v2 = mu * v_ref[...] - lr * (g_ref[...] * scale)
    v_out[...] = v2
    w_out[...] = w_ref[...] + v2


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def sgd_update(w, v, g, lr, mu, scale=1.0, block_n: int = 131072):
    """Fused momentum update on flat f32 vectors.

    `scale` multiplies the gradient first — SUBGD passes 1.0 (the LR is not
    scaled when summing updates), AWAGD-equivalent forms pass 1/k etc.
    Scalars ride in a tiny f32[4] vector block broadcast to every grid step.
    """
    (n,) = w.shape
    bn = min(block_n, _ceil_to(n, 128))
    np_ = _ceil_to(n, bn)
    pad = ((0, np_ - n),)
    wp, vp, gp = (jnp.pad(a.astype(jnp.float32), pad) for a in (w, v, g))
    s = jnp.stack(
        [
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(mu, jnp.float32),
            jnp.asarray(scale, jnp.float32),
            jnp.float32(0),
        ]
    )

    w2, v2 = pl.pallas_call(
        _sgd_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=True,
    )(wp, vp, gp, s)
    return w2[:n], v2[:n]


def apply_entry(n: int):
    """AOT entry: (w, v, g_sum, lr, mu, scale) -> (w', v') at fixed n.

    Perf note (DESIGN.md #Perf): the artifact uses ONE grid step (block =
    whole padded vector). interpret=True lowers multi-step grids to an XLA
    while-loop of dynamic-slice/update-slice over all five buffers, which
    XLA CPU executes with per-step copies — 10-100x slower than the single
    fused pass. On real TPU hardware you would restore the 128k blocking
    (VMEM residency); the kernel itself supports any block_n and the
    blocked form stays covered by python/tests.
    """

    def fn(w, v, g, lr, mu, scale):
        w2, v2 = sgd_update(w, v, g, lr, mu, scale, block_n=max(n, 128))
        return (w2, v2)

    f32 = jnp.float32
    return fn, (
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((), f32),
    )
