"""L1: Pallas k-way segment summation — the GPU-sum of Alltoall-sum-Allgather.

Paper §3.2: after the CUDA-aware Alltoall, each rank holds k sub-arrays that
must be summed; Theano-MPI runs a CUDA summation kernel (measured at 1.6 % of
total communication time). Here the same arithmetic is a Pallas kernel over a
(k, n) stack: the grid walks the n axis in VMEM-sized blocks and each block's
k-way sum stays resident — the HBM->VMEM schedule replaces the CUDA
threadblock decomposition.

The rust ASA strategy calls the AOT-compiled form of `sum_stack` on each
rank's post-Alltoall segments (runtime::kernels), so this kernel is on the L3
exchange hot path.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sum_kernel(s_ref, o_ref):
    # Block is (k, bn): the whole rank axis fits in one block so the k-way
    # sum is a single VMEM reduction per grid step.
    o_ref[...] = jnp.sum(s_ref[...], axis=0)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def sum_stack(stack, block_n: int = 65536):
    """Sum a (k, n) f32 stack over axis 0 via a blocked Pallas kernel.

    n need not be block-aligned; zero padding is exact for summation.
    """
    k, n = stack.shape
    bn = min(block_n, _ceil_to(n, 128))
    np_ = _ceil_to(n, bn)
    sp = jnp.pad(stack.astype(jnp.float32), ((0, 0), (0, np_ - n)))

    out = pl.pallas_call(
        _sum_kernel,
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(sp)
    return out[:n]


def sum_stack_entry(k: int, n: int):
    """AOT entry point: fixed (k, n) -> jitted fn + example args.

    The rust exchanger pads layer segments to `n` and loops chunks, so a
    small set of (k, n) artifacts covers all models (see aot.py)."""

    def fn(stack):
        # single grid step for the AOT artifact (see sgd.apply_entry's perf
        # note): interpret-mode multi-step grids cost per-step buffer copies
        # on XLA CPU; real-TPU builds would restore 64k blocking for VMEM.
        return (sum_stack(stack, block_n=n),)

    spec = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return fn, (spec,)


def vmem_footprint_bytes(k: int, block_n: int) -> int:
    """One grid step holds the (k, bn) input block + (bn,) output in VMEM."""
    return 4 * (k * block_n + block_n)
