"""Pure-jnp oracles for every Pallas kernel (L1 correctness ground truth).

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis and
asserts each kernel matches its oracle to tight tolerances. These oracles are
also what the kernels *replace* on the roofline: the perf notes in DESIGN.md
S-Perf compare the blocked kernels' HLO structure against these fused forms.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain GEMM oracle: f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def sumreduce_ref(stack):
    """k-way segment sum oracle: sum over the leading (rank) axis.

    This is the arithmetic half of the paper's Alltoall-sum-Allgather
    exchange: after the Alltoall, each rank holds a (k, n/k) stack of
    sub-arrays to be summed (Fig. 2)."""
    return jnp.sum(stack.astype(jnp.float32), axis=0)


def fp16_pack_ref(x, wire="f16"):
    """f32 -> half bits carried as u16 (the ASA16 wire format)."""
    dt = jnp.float16 if wire == "f16" else jnp.bfloat16
    return jax.lax.bitcast_convert_type(x.astype(dt), jnp.uint16)


def fp16_unpack_ref(bits, wire="f16"):
    """u16 half bits -> f32 (summation happens at full precision, S3.2)."""
    dt = jnp.float16 if wire == "f16" else jnp.bfloat16
    return jax.lax.bitcast_convert_type(bits, dt).astype(jnp.float32)


def sgd_update_ref(w, v, g, lr, mu, scale=1.0):
    """Classical momentum SGD: v' = mu*v - lr*(g*scale) ; w' = w + v'."""
    v2 = mu * v - lr * (g * scale)
    return w + v2, v2
