"""Flat parameter-vector layout shared between L2 (jax) and L3 (rust).

Every train-step artifact takes model parameters as ONE flat f32[N] vector so
the rust exchanger (collectives over MPI-style communicators) can operate on
the exact buffer the executable consumes — the same trick Theano-MPI used by
exchanging the concatenated list of Theano shared variables.

The layout (name, shape, offset per tensor) is recorded in the artifact
manifest so rust can segment the vector per-layer (ASA splits on layer
boundaries, mirroring the paper's per-parameter Alltoall).
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax.numpy as jnp


class ParamSpec:
    """Describes the flattening of a list of named tensors into one f32 vector."""

    def __init__(self, shapes: Sequence[Tuple[str, Tuple[int, ...]]]):
        self.names: List[str] = [n for n, _ in shapes]
        self.shapes: List[Tuple[int, ...]] = [tuple(s) for _, s in shapes]
        self.sizes: List[int] = [int(math.prod(s)) if s else 1 for s in self.shapes]
        self.offsets: List[int] = []
        off = 0
        for sz in self.sizes:
            self.offsets.append(off)
            off += sz
        self.total: int = off

    def flatten(self, tensors) -> jnp.ndarray:
        """Concatenate tensors (in spec order) into a flat f32 vector."""
        assert len(tensors) == len(self.shapes), (len(tensors), len(self.shapes))
        parts = []
        for t, s in zip(tensors, self.shapes):
            assert tuple(t.shape) == s, (tuple(t.shape), s)
            parts.append(jnp.ravel(t).astype(jnp.float32))
        return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)

    def unflatten(self, flat: jnp.ndarray):
        """Slice the flat vector back into the original tensor list (jit-safe:
        all offsets are static)."""
        out = []
        for off, sz, shape in zip(self.offsets, self.sizes, self.shapes):
            out.append(jnp.reshape(flat[off : off + sz], shape))
        return out

    def segments(self):
        """(name, offset, size) triples — the manifest's layer map."""
        return list(zip(self.names, self.offsets, self.sizes))
