"""L2: train/grad/eval step builders over flat parameter vectors.

Each builder returns a jax-jittable function whose inputs/outputs are the
exact artifact signature the rust runtime executes:

  train_step(fp, fm, x, y, lr, mu) -> (fp', fm', loss)
      the AWAGD local step: fwd/bwd + classical momentum SGD. Workers run
      this, then the exchanger AVERAGES weights+momentum (paper §4, [15,7]).

  grad_step(fp, x, y) -> (grads, loss)
      the SUBGD half-step: fwd/bwd only. Workers exchange (sum) raw
      gradients, then the sgd_apply kernel artifact applies the update once.

  eval_step(fp, x, y) -> (loss, n_correct)
      validation: mean loss + correct predictions in the batch.

GoogLeNet-style aux classifiers contribute `aux_weight`-scaled losses during
training only (train=True), matching BVLC GoogLeNet / the paper's setup.
"""

import jax

from .flatparams import ParamSpec
from .models import nn, transformer


def make_spec(model_mod, cfg) -> ParamSpec:
    return ParamSpec(model_mod.param_shapes(cfg))


def _classifier_loss(model_mod, cfg, spec, fp, x, y, train):
    logits, auxes = model_mod.apply(cfg, spec.unflatten(fp), x, train=train)
    loss = nn.cross_entropy(logits, y)
    w = cfg.get("aux_weight", 0.3)
    for a in auxes:
        loss = loss + w * nn.cross_entropy(a, y)
    return loss, logits


def make_train_step(model_mod, cfg, spec):
    def train_step(fp, fm, x, y, lr, mu):
        def loss_fn(p):
            loss, _ = _classifier_loss(model_mod, cfg, spec, p, x, y, True)
            return loss

        loss, g = jax.value_and_grad(loss_fn)(fp)
        v = mu * fm - lr * g
        return fp + v, v, loss

    return train_step


def make_grad_step(model_mod, cfg, spec):
    def grad_step(fp, x, y):
        def loss_fn(p):
            loss, _ = _classifier_loss(model_mod, cfg, spec, p, x, y, True)
            return loss

        loss, g = jax.value_and_grad(loss_fn)(fp)
        return g, loss

    return grad_step


def make_eval_step(model_mod, cfg, spec):
    def eval_step(fp, x, y):
        logits, _ = model_mod.apply(cfg, spec.unflatten(fp), x, train=False)
        loss = nn.cross_entropy(logits, y)
        return loss, nn.correct_count(logits, y)

    return eval_step


# --- transformer LM variants (targets are i32[B, L] token grids) ------------


def make_lm_train_step(cfg, spec):
    def train_step(fp, fm, x, y, lr, mu):
        def loss_fn(p):
            logits, _ = transformer.apply(cfg, spec.unflatten(p), x, train=True)
            return transformer.lm_loss(logits, y)

        loss, g = jax.value_and_grad(loss_fn)(fp)
        v = mu * fm - lr * g
        return fp + v, v, loss

    return train_step


def make_lm_grad_step(cfg, spec):
    def grad_step(fp, x, y):
        def loss_fn(p):
            logits, _ = transformer.apply(cfg, spec.unflatten(p), x, train=True)
            return transformer.lm_loss(logits, y)

        loss, g = jax.value_and_grad(loss_fn)(fp)
        return g, loss

    return grad_step


def make_lm_eval_step(cfg, spec):
    def eval_step(fp, x, y):
        logits, _ = transformer.apply(cfg, spec.unflatten(fp), x, train=False)
        loss = transformer.lm_loss(logits, y)
        return loss, transformer.token_correct(logits, y)

    return eval_step
