"""AOT compile path: jax/pallas -> HLO text artifacts + manifest.json.

Runs ONCE at `make artifacts`; the rust runtime (rust/src/runtime) loads the
HLO text via `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
client, and executes it from the L3 hot path. Python is never on the request
path.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs in --out (default ../artifacts):
  <name>.hlo.txt        one per artifact (see DESIGN.md artifact inventory)
  <model>_init.bin      raw little-endian f32 initial flat parameter vector
  manifest.json         artifact signatures + model/kernel metadata for rust
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as modellib
from .flatparams import ParamSpec
from .kernels import fp16, sgd, sumreduce
from .models import (
    alexnet_proxy,
    googlenet_proxy,
    mlp,
    registry,
    transformer,
    vgg_proxy,
)

# flat-vector chunk size shared by sum/pack kernels and rust. 1M elements:
# one PJRT call per 4 MB of exchanged parameters (65536 made the ASA hot
# path call-bound — DESIGN.md #Perf); inside a chunk the kernels still walk
# 64k-element VMEM-sized blocks.
CHUNK = 1 << 20
SUM_KS = (2, 4, 8)  # worker counts with a dedicated sum-stack artifact

# model name -> (module, kind); proxy cfgs use module defaults
MODELS = {
    "mlp": (mlp, "cls"),
    "alexnet": (alexnet_proxy, "cls"),
    "googlenet": (googlenet_proxy, "cls"),
    "vgg": (vgg_proxy, "cls"),
    "transformer": (transformer, "lm"),
}

# extra per-worker batch-size variants (paper benchmarks AlexNet at 128 and 32)
EXTRA_BATCHES = {"alexnet": [128, 8]}  # 128: Table 3; 8: the Fig. 4 small-batch recovery row


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(s) -> str:
    return {"float32": "f32", "int32": "i32", "uint16": "u16"}[str(s)]


def _sig(avals):
    return [{"shape": [int(d) for d in a.shape], "dtype": _dt(a.dtype)} for a in avals]


class Builder:
    def __init__(self, out_dir: str, only=None):
        self.out = out_dir
        self.only = only
        self.artifacts = {}

    def add(self, name: str, fn, example_args):
        """Lower fn at the example shapes and write <name>.hlo.txt."""
        if self.only and name not in self.only:
            return
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        out_avals = jax.tree_util.tree_leaves(lowered.out_info)
        self.artifacts[name] = {
            "file": fname,
            "inputs": _sig(example_args),
            "outputs": _sig(out_avals),
        }
        print(f"  [aot] {name}: {len(text)} chars, "
              f"{len(example_args)} inputs -> {len(out_avals)} outputs", flush=True)


def shaped(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def build_model_artifacts(b: Builder, name: str, mod, kind: str, manifest_models: dict):
    cfg = mod.config()
    spec = ParamSpec(mod.param_shapes(cfg))
    n = spec.total
    f32, i32 = jnp.float32, jnp.int32

    batches = [cfg["batch"]] + EXTRA_BATCHES.get(name, [])
    entries = {}
    for bs in batches:
        suffix = "" if bs == cfg["batch"] else f"{bs}"
        key = f"{name}{suffix}"
        if kind == "cls":
            x = shaped(mod.input_shape(cfg, bs), f32)
            y = shaped((bs,), i32)
            ex = shaped(mod.input_shape(cfg, cfg["eval_batch"]), f32)
            ey = shaped((cfg["eval_batch"],), i32)
            train = modellib.make_train_step(mod, cfg, spec)
            grad = modellib.make_grad_step(mod, cfg, spec)
            evals = modellib.make_eval_step(mod, cfg, spec)
        else:
            x = shaped(mod.input_shape(cfg, bs), i32)
            y = shaped(mod.input_shape(cfg, bs), i32)
            ex = shaped(mod.input_shape(cfg, cfg["eval_batch"]), i32)
            ey = shaped(mod.input_shape(cfg, cfg["eval_batch"]), i32)
            train = modellib.make_lm_train_step(cfg, spec)
            grad = modellib.make_lm_grad_step(cfg, spec)
            evals = modellib.make_lm_eval_step(cfg, spec)

        p, m = shaped((n,), f32), shaped((n,), f32)
        s = shaped((), f32)
        b.add(f"{key}_train", train, (p, m, x, y, s, s))
        b.add(f"{key}_grad", grad, (p, x, y))
        if bs == cfg["batch"]:
            b.add(f"{key}_eval", evals, (p, ex, ey))
        entries[bs] = key

    # fused momentum-SGD apply over the full flat vector (SUBGD second half)
    fn, args = sgd.apply_entry(n)
    b.add(f"sgd_apply_{name}", fn, args)

    # deterministic initial parameters, raw f32 LE
    init = spec.flatten([jnp.asarray(t) for t in mod.init_params(cfg, seed=0)])
    init_file = f"{name}_init.bin"
    np.asarray(init, dtype="<f4").tofile(os.path.join(b.out, init_file))

    manifest_models[name] = {
        "kind": kind,
        "param_count": n,
        "batch": cfg["batch"],
        "eval_batch": cfg["eval_batch"],
        "batches": {str(bs): key for bs, key in entries.items()},
        "classes": cfg.get("classes"),
        "input_shape": list(mod.input_shape(cfg, cfg["batch"])),
        "init_file": init_file,
        "segments": [[nm, off, sz] for nm, off, sz in spec.segments()],
        "sgd_apply": f"sgd_apply_{name}",
        "config": {k: v for k, v in cfg.items() if isinstance(v, (int, float, str))},
    }


def build_kernel_artifacts(b: Builder, manifest: dict):
    for k in SUM_KS:
        fn, args = sumreduce.sum_stack_entry(k, CHUNK)
        b.add(f"sum_stack_k{k}", fn, args)
    for wire in ("f16", "bf16"):
        fn, args = fp16.pack_entry(CHUNK, wire)
        b.add(f"fp16_pack_{wire}", fn, args)
        fn, args = fp16.unpack_entry(CHUNK, wire)
        b.add(f"fp16_unpack_{wire}", fn, args)
    manifest["kernels"] = {
        "chunk": CHUNK,
        "sum_stack": {str(k): f"sum_stack_k{k}" for k in SUM_KS},
        "fp16_pack": {w: f"fp16_pack_{w}" for w in ("f16", "bf16")},
        "fp16_unpack": {w: f"fp16_unpack_{w}" for w in ("f16", "bf16")},
    }


def build_full_scale(manifest: dict):
    manifest["full_scale"] = {
        name: {
            "depth": info["depth"],
            "params": registry.total_params(name),
            "paper_params": registry.PAPER_COUNTS[name],
            "batches": list(info["batches"]),
            "segments": [[nm, sz] for nm, sz in registry.segments(name)],
            # per-layer param counts in exchange order: the wait-free
            # backprop bucket boundaries (rust models::full_scale_layer_table)
            "layers": [sz for _, sz in registry.segments(name)],
        }
        for name, info in registry.FULL_SCALE.items()
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="build only these artifact names (still writes manifest)")
    ap.add_argument("--skip-models", nargs="*", default=[],
                    help="model names to skip (e.g. transformer for quick builds)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    b = Builder(args.out, only=args.only)
    manifest = {"version": 1, "models": {}}

    for name, (mod, kind) in MODELS.items():
        if name in args.skip_models:
            continue
        print(f"[aot] model {name}", flush=True)
        build_model_artifacts(b, name, mod, kind, manifest["models"])

    print("[aot] kernels", flush=True)
    build_kernel_artifacts(b, manifest)
    build_full_scale(manifest)
    manifest["artifacts"] = b.artifacts

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote manifest with {len(b.artifacts)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
